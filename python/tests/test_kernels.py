"""L1 correctness: Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes (batch, feature/vocab dims) and block sizes;
assert_allclose against ref.py is THE core correctness signal for the
kernels that end up inside every AOT artifact.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import logreg, ref
from compile.kernels.softmax_xent import softmax_xent

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("kernels")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     dtype=jnp.float32)


# ---------------------------------------------------------------- logreg --


@given(
    b=st.integers(1, 64),
    d=st.integers(1, 48),
    l2=st.sampled_from([0.0, 1e-4, 1e-2, 0.5]),
    seed=st.integers(0, 2**16),
)
def test_logreg_kernel_matches_ref(b, d, l2, seed):
    theta = _rand(seed, (d + 1,))
    x = _rand(seed + 1, (b, d))
    y = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (b,)) > 0.5
         ).astype(jnp.float32)
    lk, gk = logreg.logreg_loss_grad(theta, x, y, l2=l2)
    lr, gr = ref.logreg_loss_grad_ref(theta, x, y, l2)
    np.testing.assert_allclose(lk, lr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-6)


@given(
    tiles=st.integers(2, 5),
    blk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_logreg_kernel_multi_tile_accumulation(tiles, blk, seed):
    """Grid accumulation across batch tiles must equal the whole-batch ref."""
    b, d = tiles * blk, 12
    theta = _rand(seed, (d + 1,))
    x = _rand(seed + 1, (b, d))
    y = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (b,)) > 0.5
         ).astype(jnp.float32)
    lk, gk = logreg.logreg_loss_grad(theta, x, y, l2=1e-3, batch_block=blk)
    lr, gr = ref.logreg_loss_grad_ref(theta, x, y, 1e-3)
    np.testing.assert_allclose(lk, lr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-6)


def test_logreg_kernel_shape_mismatch_raises():
    with pytest.raises(ValueError):
        logreg.logreg_loss_grad(jnp.zeros(5), jnp.zeros((4, 8)),
                                jnp.zeros(4), l2=0.0)


def test_logreg_kernel_extreme_logits_stable():
    """BCE must not produce inf/nan for |z| >> 0 (stable formulation)."""
    d = 4
    theta = jnp.concatenate([jnp.full((d,), 50.0), jnp.zeros(1)])
    x = jnp.ones((8, d))
    y = jnp.concatenate([jnp.zeros(4), jnp.ones(4)])
    loss, grad = logreg.logreg_loss_grad(theta, x, y, l2=0.0)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grad)))


def test_logreg_kernel_under_jit():
    """The kernel must lower inside jit — the exact AOT configuration."""
    b, d = 32, 16
    theta, x = _rand(0, (d + 1,)), _rand(1, (b, d))
    y = jnp.zeros(b)
    fn = jax.jit(lambda t, xx, yy: logreg.logreg_loss_grad(t, xx, yy, l2=1e-4))
    lk, gk = fn(theta, x, y)
    lr, gr = ref.logreg_loss_grad_ref(theta, x, y, 1e-4)
    np.testing.assert_allclose(lk, lr, rtol=1e-5)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------- softmax_xent --


@given(
    b=st.integers(1, 40),
    v=st.integers(2, 300),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_xent_forward_matches_ref(b, v, scale, seed):
    logits = _rand(seed, (b, v), scale)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, v)
    lk = softmax_xent(logits, labels)
    lr = ref.softmax_xent_ref(logits, labels)
    np.testing.assert_allclose(lk, lr, rtol=1e-5, atol=1e-6)


@given(
    b=st.integers(1, 24),
    v=st.integers(2, 200),
    seed=st.integers(0, 2**16),
)
def test_xent_grad_matches_ref(b, v, seed):
    logits = _rand(seed, (b, v))
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, v)
    gk = jax.grad(lambda lg: softmax_xent(lg, labels))(logits)
    gr = ref.softmax_xent_grad_ref(logits, labels, jnp.float32(1.0))
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-6)


def test_xent_grad_matches_jax_autodiff_of_ref():
    """Triangulate: kernel VJP vs jax autodiff of the jnp reference."""
    b, v = 16, 64
    logits = _rand(7, (b, v))
    labels = jax.random.randint(jax.random.PRNGKey(8), (b,), 0, v)
    gk = jax.grad(lambda lg: softmax_xent(lg, labels))(logits)
    ga = jax.grad(lambda lg: ref.softmax_xent_ref(lg, labels))(logits)
    np.testing.assert_allclose(gk, ga, rtol=1e-4, atol=1e-6)


def test_xent_row_block_invariance():
    """Different row-tilings must give identical results."""
    b, v = 24, 100
    logits = _rand(3, (b, v))
    labels = jax.random.randint(jax.random.PRNGKey(4), (b,), 0, v)
    base = softmax_xent(logits, labels, 24)
    for blk in (1, 2, 3, 4, 6, 8, 12):
        np.testing.assert_allclose(softmax_xent(logits, labels, blk), base,
                                   rtol=1e-6)


def test_xent_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]],
                       dtype=jnp.float32)
    labels = jnp.array([0, 0], dtype=jnp.int32)
    loss = softmax_xent(logits, labels)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda lg: softmax_xent(lg, labels))(logits)
    assert np.all(np.isfinite(np.asarray(g)))


def test_xent_value_and_grad_consistent_under_jit():
    b, v = 8, 32
    logits = _rand(9, (b, v))
    labels = jax.random.randint(jax.random.PRNGKey(10), (b,), 0, v)
    loss, g = jax.jit(jax.value_and_grad(
        lambda lg: softmax_xent(lg, labels)))(logits)
    np.testing.assert_allclose(loss, ref.softmax_xent_ref(logits, labels),
                               rtol=1e-5)
    np.testing.assert_allclose(
        g, ref.softmax_xent_grad_ref(logits, labels, jnp.float32(1.0)),
        rtol=1e-4, atol=1e-6)
