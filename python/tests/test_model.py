"""L2 correctness: flat-θ models — kernel vs ref lowering, gradient checks,
shape contracts that the rust runtime depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _data(b=16, d=784, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, d), dtype=jnp.float32)
    y = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (b,)) > 0.5
         ).astype(jnp.float32)
    return x, y


# ------------------------------------------------------------- ParamSpec --


def test_paramspec_roundtrip():
    spec = model.spec_from_pairs([("a", (3, 4)), ("b", (5,)), ("c", (2, 2, 2))])
    assert spec.total == 12 + 5 + 8
    theta = jnp.arange(spec.total, dtype=jnp.float32)
    p = spec.unflatten(theta)
    assert p["a"].shape == (3, 4) and p["c"].shape == (2, 2, 2)
    np.testing.assert_array_equal(spec.flatten(p), theta)


def test_paramspec_unflatten_is_differentiable():
    spec = model.spec_from_pairs([("w", (4, 2)), ("b", (2,))])
    theta = jnp.ones(spec.total)

    def f(t):
        p = spec.unflatten(t)
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] * 3.0)

    g = jax.grad(f)(theta)
    np.testing.assert_allclose(g[:8], 2.0)
    np.testing.assert_allclose(g[8:], 3.0)


# ---------------------------------------------------------------- logreg --


def test_logreg_grad_matches_autodiff():
    theta = model.logreg_init(jax.random.PRNGKey(0))
    x, y = _data()

    def pure_loss(t):
        l, _ = model.logreg_grad(t, x, y, use_kernel=False)
        return l

    _, g_kernel = model.logreg_grad(theta, x, y)
    g_auto = jax.grad(pure_loss)(theta)
    np.testing.assert_allclose(g_kernel, g_auto, rtol=1e-4, atol=1e-6)


def test_logreg_eval_counts():
    theta = jnp.zeros(model.LOGREG_P)
    x, y = _data(b=32)
    _, correct = model.logreg_eval(theta, x, y)
    # zero weights → logit 0 → predict class 0 everywhere
    expected = int(np.sum(np.asarray(y) == 0.0))
    assert int(correct) == expected


def test_logreg_sgd_descends():
    theta = model.logreg_init(jax.random.PRNGKey(1))
    x, y = _data(b=64, seed=3)
    l0, _ = model.logreg_grad(theta, x, y)
    for _ in range(50):
        _, g = model.logreg_grad(theta, x, y)
        theta = theta - 0.5 * g
    l1, _ = model.logreg_grad(theta, x, y)
    assert float(l1) < float(l0) * 0.7


# ------------------------------------------------------------------- mlp --


def test_mlp_param_count():
    dims = model.MLP_DIMS
    expect = sum(dims[i] * dims[i + 1] + dims[i + 1]
                 for i in range(len(dims) - 1))
    assert model.MLP_P == expect


def test_mlp_kernel_vs_ref_lowering():
    theta = model.mlp_init(jax.random.PRNGKey(2))
    x, _ = _data(b=8)
    labels = jax.random.randint(jax.random.PRNGKey(5), (8,), 0, 10)
    lk, gk = model.mlp_grad(theta, x, labels, use_kernel=True)
    lr, gr = model.mlp_grad(theta, x, labels, use_kernel=False)
    np.testing.assert_allclose(lk, lr, rtol=1e-5)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-6)


def test_mlp_eval_correct_upper_bound():
    theta = model.mlp_init(jax.random.PRNGKey(3))
    x, _ = _data(b=32)
    labels = jax.random.randint(jax.random.PRNGKey(6), (32,), 0, 10)
    loss, correct = model.mlp_eval(theta, x, labels)
    assert 0 <= int(correct) <= 32
    assert float(loss) > 0.0


def test_mlp_sgd_descends():
    theta = model.mlp_init(jax.random.PRNGKey(4))
    x, _ = _data(b=64, seed=9)
    labels = jax.random.randint(jax.random.PRNGKey(7), (64,), 0, 10)
    grad_fn = jax.jit(lambda t: model.mlp_grad(t, x, labels))
    l0, g = grad_fn(theta)
    for _ in range(30):
        _, g = grad_fn(theta)
        theta = theta - 0.1 * g
    l1, _ = grad_fn(theta)
    assert float(l1) < float(l0)


# ----------------------------------------------------------- transformer --


@pytest.fixture(scope="module")
def tiny():
    cfg = model.TRANSFORMER_CONFIGS["tiny"]
    theta = model.transformer_init(jax.random.PRNGKey(11), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(12),
                              (cfg.batch, cfg.seq + 1), 0, cfg.vocab)
    return cfg, theta, toks


def test_transformer_spec_total_matches_init(tiny):
    cfg, theta, _ = tiny
    assert theta.shape == (model.transformer_spec(cfg).total,)


def test_transformer_initial_loss_near_uniform(tiny):
    """Random init ⇒ loss ≈ log(vocab)."""
    cfg, theta, toks = tiny
    loss = model.transformer_loss(theta, toks, cfg, use_kernel=False)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_transformer_kernel_vs_ref_lowering(tiny):
    cfg, theta, toks = tiny
    lk, gk = model.transformer_grad(theta, toks, cfg, use_kernel=True)
    lr, gr = model.transformer_grad(theta, toks, cfg, use_kernel=False)
    np.testing.assert_allclose(lk, lr, rtol=1e-4)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-5)


def test_transformer_causality(tiny):
    """Changing a future token must not change earlier positions' logits
    (verified through the loss: perturb the LAST input token and check the
    per-position losses before it are unchanged)."""
    cfg, theta, toks = tiny

    def per_pos_losses(tokens):
        # re-implement loss per position with ref xent
        from compile.kernels import ref as kref
        spec = model.transformer_spec(cfg)
        # reuse internal forward by calling transformer_loss on 1-batch slices
        return model.transformer_loss(theta, tokens, cfg, use_kernel=False)

    t2 = np.asarray(toks).copy()
    t2[:, -1] = (t2[:, -1] + 1) % cfg.vocab
    # loss over positions 0..S-2 unchanged ⇒ total loss differs only via the
    # last position term, bounded by (max per-token xent)/S.
    l1 = float(model.transformer_loss(theta, toks, cfg, use_kernel=False))
    l2 = float(model.transformer_loss(theta, jnp.asarray(t2), cfg,
                                      use_kernel=False))
    # crude but effective: last-token change can move mean loss at most by
    # ~(2*log V)/S; a causality bug (full attention) moves every position.
    assert abs(l1 - l2) < 2.5 * np.log(cfg.vocab) / cfg.seq + 1e-3


def test_transformer_sgd_descends(tiny):
    cfg, theta, toks = tiny
    grad_fn = jax.jit(lambda t: model.transformer_grad(t, toks, cfg,
                                                       use_kernel=False))
    l0, _ = grad_fn(theta)
    for _ in range(10):
        _, g = grad_fn(theta)
        theta = theta - 0.5 * g
    l1, _ = grad_fn(theta)
    assert float(l1) < float(l0)


def test_transformer_configs_param_counts():
    # sanity: documented scales
    p_tiny = model.transformer_spec(model.TRANSFORMER_CONFIGS["tiny"]).total
    p_e2e = model.transformer_spec(model.TRANSFORMER_CONFIGS["e2e"]).total
    p_large = model.transformer_spec(model.TRANSFORMER_CONFIGS["large"]).total
    assert 3e5 < p_tiny < 1e6
    assert 3e6 < p_e2e < 1e7
    assert 8e7 < p_large < 1.2e8
