"""AOT pipeline checks: lowering produces loadable HLO text and a manifest
that matches the shapes the rust runtime will feed."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_contains_entry():
    lowered = jax.jit(lambda a, b: (a + b,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_to_hlo_text_logreg_has_fused_outputs():
    import functools
    p, d, b = model.LOGREG_P, model.LOGREG_DIM, 8
    lowered = jax.jit(functools.partial(model.logreg_grad,
                                        use_kernel=True)).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    # tuple of (scalar loss, grad[p])
    assert f"f32[{p}]" in text
    assert "ENTRY" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.m = json.load(f)

    def test_required_artifacts_present(self):
        for name in ("logreg_grad", "logreg_eval", "mlp_grad", "mlp_eval",
                     "transformer_tiny_grad", "transformer_tiny_eval"):
            assert name in self.m["artifacts"], name
            path = os.path.join(ART, self.m["artifacts"][name]["hlo"])
            assert os.path.exists(path)
            with open(path) as f:
                assert "ENTRY" in f.read()

    def test_logreg_grad_shapes(self):
        a = self.m["artifacts"]["logreg_grad"]
        assert a["inputs"][0]["shape"] == [model.LOGREG_P]
        assert a["inputs"][1]["shape"] == [aot.GRAD_BATCH, model.LOGREG_DIM]
        assert a["outputs"][0]["shape"] == []
        assert a["outputs"][1]["shape"] == [model.LOGREG_P]

    def test_init_files_match_p(self):
        for mname, info in self.m["models"].items():
            path = os.path.join(ART, info["init"])
            raw = np.fromfile(path, dtype="<f4")
            assert raw.shape[0] == info["p"], mname
            assert np.all(np.isfinite(raw)), mname

    def test_label_dtypes_are_int32_where_needed(self):
        assert self.m["artifacts"]["mlp_grad"]["inputs"][2]["dtype"] == "int32"
        assert (self.m["artifacts"]["transformer_tiny_grad"]["inputs"][1]
                ["dtype"] == "int32")
        # logreg labels are float targets in {0,1}
        assert (self.m["artifacts"]["logreg_grad"]["inputs"][2]["dtype"]
                == "float32")
