"""L2: training models over a FLAT parameter vector θ ∈ R^p.

R-FAST (the L3 coordinator) manipulates flat vectors — x_i, z_i, ρ_ij all
live in R^p — so every model here exposes exactly two jit-able entrypoints
operating on a flat θ:

    <model>_grad(θ, batch...)  -> (scalar loss, grad ∈ R^p)
    <model>_eval(θ, batch...)  -> (scalar loss[, #correct])

The unflatten is differentiable slicing, so ``jax.grad`` over θ is exact.
Compute hot spots route through the L1 Pallas kernels
(``use_kernel=False`` swaps in the pure-jnp references, used by pytest to
cross-check the full lowering).

Models:
  logreg       785-dim regularized logistic regression (paper §VI-A)
  mlp          784-128-64-10 classifier (ResNet/ImageNet *coordination*
               proxy, paper §VI-B — see DESIGN.md §4)
  transformer  decoder-only LM, tied embeddings (e2e driver; configurable
               scale tiny/e2e/large)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import logreg as logreg_kernel
from .kernels import ref as kref
from .kernels.softmax_xent import softmax_xent

# --------------------------------------------------------------------------
# Flat-parameter plumbing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Named shapes making up a flat parameter vector."""

    names: tuple[str, ...]
    shapes: tuple[tuple[int, ...], ...]

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(int(math.prod(s)) for s in self.shapes)

    @property
    def total(self) -> int:
        return sum(self.sizes)

    def unflatten(self, theta: jax.Array) -> dict[str, jax.Array]:
        out: dict[str, jax.Array] = {}
        off = 0
        for name, shape, size in zip(self.names, self.shapes, self.sizes):
            out[name] = theta[off:off + size].reshape(shape)
            off += size
        return out

    def flatten(self, params: dict[str, jax.Array]) -> jax.Array:
        return jnp.concatenate([params[n].reshape(-1) for n in self.names])


def spec_from_pairs(pairs: Sequence[tuple[str, tuple[int, ...]]]) -> ParamSpec:
    return ParamSpec(tuple(n for n, _ in pairs), tuple(s for _, s in pairs))


# --------------------------------------------------------------------------
# Logistic regression (paper §VI-A: MNIST 0-vs-1, smooth strongly convex)
# --------------------------------------------------------------------------

LOGREG_DIM = 784          # feature dim (28×28 flattened)
LOGREG_P = LOGREG_DIM + 1  # +bias
LOGREG_L2 = 1e-4           # the "regularized" in regularized logreg


def logreg_grad(theta: jax.Array, x: jax.Array, y: jax.Array, *,
                l2: float = LOGREG_L2,
                use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    if use_kernel:
        return logreg_kernel.logreg_loss_grad(theta, x, y, l2=l2)
    return kref.logreg_loss_grad_ref(theta, x, y, l2)


def logreg_eval(theta: jax.Array, x: jax.Array, y: jax.Array, *,
                l2: float = LOGREG_L2) -> tuple[jax.Array, jax.Array]:
    return kref.logreg_eval_ref(theta, x, y, l2)


def logreg_init(key: jax.Array) -> jax.Array:
    return 0.01 * jax.random.normal(key, (LOGREG_P,), dtype=jnp.float32)


# --------------------------------------------------------------------------
# MLP classifier (ImageNet/ResNet coordination proxy, paper §VI-B)
# --------------------------------------------------------------------------

MLP_DIMS = (784, 128, 64, 10)


def mlp_spec(dims: Sequence[int] = MLP_DIMS) -> ParamSpec:
    pairs: list[tuple[str, tuple[int, ...]]] = []
    for i in range(len(dims) - 1):
        pairs.append((f"w{i}", (dims[i], dims[i + 1])))
        pairs.append((f"b{i}", (dims[i + 1],)))
    return spec_from_pairs(pairs)


MLP_SPEC = mlp_spec()
MLP_P = MLP_SPEC.total


def _mlp_logits(p: dict[str, jax.Array], x: jax.Array,
                n_layers: int) -> jax.Array:
    h = x
    for i in range(n_layers):
        h = h @ p[f"w{i}"] + p[f"b{i}"]
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    return h


def mlp_loss(theta: jax.Array, x: jax.Array, labels: jax.Array, *,
             use_kernel: bool = True) -> jax.Array:
    p = MLP_SPEC.unflatten(theta)
    logits = _mlp_logits(p, x, len(MLP_DIMS) - 1)
    if use_kernel:
        return softmax_xent(logits, labels)
    return kref.softmax_xent_ref(logits, labels)


def mlp_grad(theta: jax.Array, x: jax.Array, labels: jax.Array, *,
             use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    return jax.value_and_grad(mlp_loss)(theta, x, labels,
                                        use_kernel=use_kernel)


def mlp_eval(theta: jax.Array, x: jax.Array,
             labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    p = MLP_SPEC.unflatten(theta)
    logits = _mlp_logits(p, x, len(MLP_DIMS) - 1)
    loss = kref.softmax_xent_ref(logits, labels)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels)
                      .astype(jnp.int32))
    return loss, correct


def mlp_init(key: jax.Array) -> jax.Array:
    parts = []
    dims = MLP_DIMS
    keys = jax.random.split(key, len(dims) - 1)
    for i in range(len(dims) - 1):
        scale = math.sqrt(2.0 / dims[i])
        parts.append(scale * jax.random.normal(
            keys[i], (dims[i] * dims[i + 1],), dtype=jnp.float32))
        parts.append(jnp.zeros((dims[i + 1],), dtype=jnp.float32))
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Decoder-only transformer LM (e2e driver)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int
    seq: int      # training context length (tokens fed = seq+1)
    batch: int
    d_ff: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


TRANSFORMER_CONFIGS = {
    # ~1.3M params — unit tests / CI.
    "tiny": TransformerConfig("tiny", d_model=128, n_layers=2, n_heads=4,
                              vocab=512, seq=64, batch=8, d_ff=512),
    # ~13M params — the e2e example's default (DESIGN.md §4).
    "e2e": TransformerConfig("e2e", d_model=256, n_layers=4, n_heads=8,
                             vocab=4096, seq=128, batch=8, d_ff=1024),
    # ~97M params — full-scale config (slow on CPU; lowered on request).
    "large": TransformerConfig("large", d_model=768, n_layers=12, n_heads=12,
                               vocab=16384, seq=256, batch=8, d_ff=3072),
}


def transformer_spec(cfg: TransformerConfig) -> ParamSpec:
    d, f = cfg.d_model, cfg.d_ff
    pairs: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (cfg.vocab, d)),        # tied with the LM head
        ("pos", (cfg.seq, d)),
    ]
    for i in range(cfg.n_layers):
        pairs += [
            (f"ln1_g{i}", (d,)), (f"ln1_b{i}", (d,)),
            (f"wqkv{i}", (d, 3 * d)), (f"wo{i}", (d, d)),
            (f"ln2_g{i}", (d,)), (f"ln2_b{i}", (d,)),
            (f"wff1{i}", (d, f)), (f"bff1{i}", (f,)),
            (f"wff2{i}", (f, d)), (f"bff2{i}", (d,)),
        ]
    pairs += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return spec_from_pairs(pairs)


def _layernorm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _attention(x: jax.Array, wqkv: jax.Array, wo: jax.Array,
               cfg: TransformerConfig) -> jax.Array:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ wqkv                                    # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # [b, h, s, hd]
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def transformer_loss(theta: jax.Array, tokens: jax.Array,
                     cfg: TransformerConfig, *,
                     use_kernel: bool = True) -> jax.Array:
    """tokens: [B, seq+1] int32; next-token cross-entropy over seq positions."""
    spec = transformer_spec(cfg)
    p = spec.unflatten(theta)
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    b, s = inp.shape

    x = p["embed"][inp] + p["pos"][None, :s]
    for i in range(cfg.n_layers):
        h = _layernorm(x, p[f"ln1_g{i}"], p[f"ln1_b{i}"])
        x = x + _attention(h, p[f"wqkv{i}"], p[f"wo{i}"], cfg)
        h = _layernorm(x, p[f"ln2_g{i}"], p[f"ln2_b{i}"])
        h = jax.nn.gelu(h @ p[f"wff1{i}"] + p[f"bff1{i}"])
        x = x + h @ p[f"wff2{i}"] + p[f"bff2{i}"]
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = x @ p["embed"].T                          # tied head: [b, s, V]

    flat_logits = logits.reshape(b * s, cfg.vocab)
    flat_tgt = tgt.reshape(b * s)
    if use_kernel:
        return softmax_xent(flat_logits, flat_tgt)
    return kref.softmax_xent_ref(flat_logits, flat_tgt)


def transformer_grad(theta: jax.Array, tokens: jax.Array,
                     cfg: TransformerConfig, *,
                     use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    return jax.value_and_grad(transformer_loss)(theta, tokens, cfg,
                                                use_kernel=use_kernel)


def transformer_eval(theta: jax.Array, tokens: jax.Array,
                     cfg: TransformerConfig) -> tuple[jax.Array]:
    return (transformer_loss(theta, tokens, cfg, use_kernel=False),)


def transformer_init(key: jax.Array, cfg: TransformerConfig) -> jax.Array:
    spec = transformer_spec(cfg)
    params: dict[str, jax.Array] = {}
    keys = iter(jax.random.split(key, len(spec.names)))
    for name, shape in zip(spec.names, spec.shapes):
        k = next(keys)
        if "_g" in name:                      # layernorm gains
            params[name] = jnp.ones(shape, dtype=jnp.float32)
        elif name.startswith("b") or "_b" in name:  # biases, layernorm shifts
            params[name] = jnp.zeros(shape, dtype=jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.d_model
            scale = 0.02 if name in ("embed", "pos") else 1.0 / math.sqrt(fan_in)
            params[name] = scale * jax.random.normal(k, shape,
                                                     dtype=jnp.float32)
    return spec.flatten(params)
