"""AOT lowering: L2/L1 python stack → HLO-text artifacts for the rust runtime.

Run once at build time (`make artifacts`); python never appears on the
request path. For every model entrypoint we emit:

  artifacts/<name>.hlo.txt     HLO *text* — xla_extension 0.5.1 rejects
                               jax≥0.5 serialized protos (64-bit ids); the
                               text parser reassigns ids and round-trips
                               (see /opt/xla-example/README.md).
  artifacts/<model>_init.f32   raw little-endian f32 initial θ (jax init,
                               so rust never needs to know init scales).
  artifacts/manifest.json      machine-readable index: per-artifact input/
                               output shapes+dtypes, p, model metadata.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--transformer-scale tiny|e2e|large] [--only NAME]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Batch sizes baked into the artifacts (one executable per shape).
GRAD_BATCH = 32    # paper: mini-batch 32 per node
EVAL_BATCH = 256   # held-out evaluation chunk


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format).

    MLIR *bytecode* (not textual asm) goes into the converter: the textual
    pretty-form printed by current jaxlib is not always re-parseable by the
    bundled StableHLO parser (e.g. `dynamic_slice` attribute spelling),
    while bytecode round-trips across versions.
    """
    from jax._src.interpreters import mlir as jmlir

    mlir_mod = lowered.compiler_ir("stablehlo")
    bytecode = jmlir.module_to_bytecode(mlir_mod)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        bytecode, use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": np.dtype(s.dtype).name}


class Emitter:
    def __init__(self, out_dir: str, only: str | None):
        self.out_dir = out_dir
        self.only = only
        self.manifest: dict = {"artifacts": {}, "models": {}}

    def emit(self, name: str, fn, arg_specs, out_specs, meta: dict):
        if self.only and self.only != name:
            return
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "hlo": path,
            "inputs": [_shape_entry(s) for s in arg_specs],
            "outputs": [_shape_entry(s) for s in out_specs],
            "meta": meta,
        }
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text "
              f"({time.time() - t0:.1f}s)")

    def emit_init(self, model_name: str, theta: jax.Array, extra: dict):
        path = f"{model_name}_init.f32"
        np.asarray(theta, dtype="<f4").tofile(os.path.join(self.out_dir, path))
        self.manifest["models"][model_name] = {
            "init": path, "p": int(theta.shape[0]), **extra}

    def write_manifest(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)


def emit_logreg(em: Emitter):
    p, d = model.LOGREG_P, model.LOGREG_DIM
    f32 = jnp.float32
    em.emit(
        "logreg_grad",
        functools.partial(model.logreg_grad, use_kernel=True),
        (_sds((p,), f32), _sds((GRAD_BATCH, d), f32), _sds((GRAD_BATCH,), f32)),
        (_sds((), f32), _sds((p,), f32)),
        {"model": "logreg", "l2": model.LOGREG_L2, "batch": GRAD_BATCH},
    )
    em.emit(
        "logreg_eval",
        model.logreg_eval,
        (_sds((p,), f32), _sds((EVAL_BATCH, d), f32), _sds((EVAL_BATCH,), f32)),
        (_sds((), f32), _sds((), jnp.int32)),
        {"model": "logreg", "batch": EVAL_BATCH},
    )
    em.emit_init("logreg", model.logreg_init(jax.random.PRNGKey(42)),
                 {"feature_dim": d, "grad_batch": GRAD_BATCH,
                  "eval_batch": EVAL_BATCH, "l2": model.LOGREG_L2})


def emit_mlp(em: Emitter):
    p, d = model.MLP_P, model.MLP_DIMS[0]
    f32, i32 = jnp.float32, jnp.int32
    em.emit(
        "mlp_grad",
        functools.partial(model.mlp_grad, use_kernel=True),
        (_sds((p,), f32), _sds((GRAD_BATCH, d), f32), _sds((GRAD_BATCH,), i32)),
        (_sds((), f32), _sds((p,), f32)),
        {"model": "mlp", "dims": list(model.MLP_DIMS), "batch": GRAD_BATCH},
    )
    em.emit(
        "mlp_eval",
        model.mlp_eval,
        (_sds((p,), f32), _sds((EVAL_BATCH, d), f32), _sds((EVAL_BATCH,), i32)),
        (_sds((), f32), _sds((), i32)),
        {"model": "mlp", "batch": EVAL_BATCH},
    )
    em.emit_init("mlp", model.mlp_init(jax.random.PRNGKey(43)),
                 {"feature_dim": d, "classes": model.MLP_DIMS[-1],
                  "grad_batch": GRAD_BATCH, "eval_batch": EVAL_BATCH})


def emit_transformer(em: Emitter, scale: str):
    cfg = model.TRANSFORMER_CONFIGS[scale]
    spec = model.transformer_spec(cfg)
    p = spec.total
    f32, i32 = jnp.float32, jnp.int32
    tok_shape = (cfg.batch, cfg.seq + 1)
    name = f"transformer_{scale}"
    em.emit(
        f"{name}_grad",
        functools.partial(model.transformer_grad, cfg=cfg, use_kernel=True),
        (_sds((p,), f32), _sds(tok_shape, i32)),
        (_sds((), f32), _sds((p,), f32)),
        {"model": name, "config": cfg.__dict__, "batch": cfg.batch},
    )
    em.emit(
        f"{name}_eval",
        functools.partial(model.transformer_eval, cfg=cfg),
        (_sds((p,), f32), _sds(tok_shape, i32)),
        (_sds((), f32),),
        {"model": name, "config": cfg.__dict__},
    )
    em.emit_init(name, model.transformer_init(jax.random.PRNGKey(44), cfg),
                 {"config": cfg.__dict__, "grad_batch": cfg.batch,
                  "tokens_per_example": cfg.seq + 1})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--transformer-scale", default="e2e",
                    choices=sorted(model.TRANSFORMER_CONFIGS))
    ap.add_argument("--only", default=None,
                    help="emit a single artifact by name (debugging)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    em = Emitter(args.out_dir, args.only)
    print("AOT lowering (HLO text):")
    emit_logreg(em)
    emit_mlp(em)
    emit_transformer(em, "tiny")          # always: unit/integration tests
    if args.transformer_scale != "tiny":
        emit_transformer(em, args.transformer_scale)
    em.write_manifest()
    print(f"manifest: {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
