"""Pallas kernel: fused softmax-cross-entropy with custom VJP (L1 hot spot).

Used as the loss head of both the MLP classifier and the transformer LM —
for a V-way head this is the memory-bandwidth hot spot of the step
(logits are [B·S, V]; V up to 16k in the `large` transformer config).

Forward kernel (row-tiled over the batch dimension):
    m_i   = max_v logits[i, v]
    lse_i = m_i + log Σ_v exp(logits[i, v] − m_i)
    loss  = mean_i (lse_i − logits[i, label_i])
and it *saves only (m, lse)* — [B] each — as residuals.

Backward kernel recomputes softmax from (m, lse) instead of materializing
[B, V] probabilities to HBM (DESIGN.md §5: the TPU-side rematerialization
counterpart of keeping probs in CUDA shared memory):
    dlogits[i, v] = (exp(logits[i, v] − lse_i) − 1[v == label_i]) · g / B

Both kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic);
the BlockSpecs still express the intended VMEM tiling: a row-block of
(block_b, V) f32 at V=16k, block_b=8 is 512 KiB — within VMEM budget
alongside the [block_b] residuals.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["softmax_xent", "DEFAULT_ROW_BLOCK"]

DEFAULT_ROW_BLOCK = 8


def _pick_block(b: int, requested: int) -> int:
    """Largest divisor of b that is ≤ requested (grid needs exact tiling)."""
    blk = min(requested, b)
    while b % blk != 0:
        blk -= 1
    return blk


def _fwd_kernel(logits_ref, labels_ref, loss_ref, m_ref, lse_ref, *,
                total_b: int):
    step = pl.program_id(0)
    logits = logits_ref[...]
    labels = labels_ref[...]

    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    tile_loss = jnp.sum(lse - gold) / total_b

    m_ref[...] = m
    lse_ref[...] = lse

    @pl.when(step == 0)
    def _init():
        loss_ref[...] = tile_loss

    @pl.when(step != 0)
    def _accum():
        loss_ref[...] += tile_loss


def _bwd_kernel(logits_ref, labels_ref, lse_ref, g_ref, dlogits_ref, *,
                total_b: int):
    logits = logits_ref[...]
    labels = labels_ref[...]
    lse = lse_ref[...]
    g = g_ref[...]

    p = jnp.exp(logits - lse[:, None])
    v = logits.shape[-1]
    onehot = (labels[:, None] == jnp.arange(v, dtype=labels.dtype)[None, :])
    dlogits_ref[...] = (p - onehot.astype(logits.dtype)) * (g / total_b)


def _fwd_call(logits: jax.Array, labels: jax.Array, row_block: int):
    b, v = logits.shape
    blk = _pick_block(b, row_block)
    grid = (b // blk,)
    kernel = functools.partial(_fwd_kernel, total_b=b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, v), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((), lambda i: ()),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((), logits.dtype),
            jax.ShapeDtypeStruct((b,), logits.dtype),
            jax.ShapeDtypeStruct((b,), logits.dtype),
        ],
        interpret=True,
    )(logits, labels)


def _bwd_call(logits: jax.Array, labels: jax.Array, lse: jax.Array,
              g: jax.Array, row_block: int) -> jax.Array:
    b, v = logits.shape
    blk = _pick_block(b, row_block)
    grid = (b // blk,)
    kernel = functools.partial(_bwd_kernel, total_b=b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, v), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((), lambda i: ()),   # upstream scalar cotangent
        ],
        out_specs=pl.BlockSpec((blk, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, v), logits.dtype),
        interpret=True,
    )(logits, labels, lse, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 row_block: int = DEFAULT_ROW_BLOCK) -> jax.Array:
    """Mean softmax cross-entropy over rows; differentiable w.r.t. logits."""
    loss, _m, _lse = _fwd_call(logits, labels, row_block)
    return loss


def _vjp_fwd(logits, labels, row_block):
    loss, _m, lse = _fwd_call(logits, labels, row_block)
    return loss, (logits, labels, lse)


def _vjp_bwd(row_block, residuals, g):
    logits, labels, lse = residuals
    dlogits = _bwd_call(logits, labels, lse, g, row_block)
    return dlogits, None


softmax_xent.defvjp(_vjp_fwd, _vjp_bwd)
