"""Pallas kernel: fused logistic-regression loss + gradient (L1 hot spot).

One kernel invocation computes, for a node-local minibatch:

    z    = X @ w + b
    loss = mean(BCE(z, y)) + l2/2 * ||θ||²
    grad = [Xᵀ(σ(z) − y)/B + l2·w ;  Σ(σ(z) − y)/B + l2·b]

i.e. the entire per-wake compute of R-FAST step (S1)'s stochastic gradient,
fused so the activations never round-trip to HBM between the forward BCE
and the backward GEMV.

TPU adaptation (DESIGN.md §5): the two matrix products (X·w and Xᵀ·r) are
the MXU work; a (B=32, d=784) f32 block is ~100 KiB so a whole batch block
sits in VMEM and the kernel runs as a single grid step — the BlockSpecs
below express exactly that HBM→VMEM schedule. We keep the grid explicit
(batch-tiled) so larger B lowers to multiple VMEM-resident tiles with the
loss/grad accumulated across tiles.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret lowering turns the kernel body into plain fused HLO
which is what the rust runtime executes (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["logreg_loss_grad", "DEFAULT_BATCH_BLOCK"]

# Rows of X per grid step. 32 rows × 784 f32 features ≈ 100 KiB: comfortably
# VMEM-resident together with θ (≈3 KiB) and the grad accumulator.
DEFAULT_BATCH_BLOCK = 32


def _kernel(theta_ref, x_ref, y_ref, loss_ref, grad_ref, *, l2: float,
            total_b: int):
    """One batch tile: accumulate loss and grad into the outputs.

    Grid iterates over batch tiles; outputs map every grid step onto the
    same (only) block, so `+=` accumulation across steps is well-defined
    under the sequential-grid semantics Pallas guarantees on TPU.
    """
    step = pl.program_id(0)

    theta = theta_ref[...]
    w = theta[:-1]
    b = theta[-1]
    x = x_ref[...]
    y = y_ref[...]

    # Forward: logits for this tile (MXU matvec), stable BCE.
    z = x @ w + b
    bce = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))

    # Backward: residual r = (σ(z) − y)/B, then the transposed product.
    s = jax.nn.sigmoid(z)
    r = (s - y) / total_b
    gw = x.T @ r
    gb = jnp.sum(r)

    tile_loss = jnp.sum(bce) / total_b
    tile_grad = jnp.concatenate([gw, gb[None]])

    @pl.when(step == 0)
    def _init():
        # Fold the ℓ2 term in exactly once, on the first tile.
        loss_ref[...] = tile_loss + 0.5 * l2 * jnp.sum(theta * theta)
        grad_ref[...] = tile_grad + l2 * theta

    @pl.when(step != 0)
    def _accum():
        loss_ref[...] += tile_loss
        grad_ref[...] += tile_grad


def logreg_loss_grad(theta: jax.Array, x: jax.Array, y: jax.Array, *,
                     l2: float,
                     batch_block: int = DEFAULT_BATCH_BLOCK
                     ) -> tuple[jax.Array, jax.Array]:
    """Fused loss+grad via the Pallas kernel. Shapes as in ref.py.

    Requires ``B % batch_block == 0`` (callers pad or pick a divisor; the
    AOT artifacts use B=32 with one tile).
    """
    b_total, d = x.shape
    if theta.shape != (d + 1,):
        raise ValueError(f"theta shape {theta.shape} != ({d + 1},)")
    if b_total % batch_block != 0:
        # Fall back to a single whole-batch tile rather than silently
        # mis-tiling: pallas grids need exact division.
        batch_block = b_total
    grid = (b_total // batch_block,)

    kernel = functools.partial(_kernel, l2=l2, total_b=b_total)
    loss, grad = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d + 1,), lambda i: (0,)),          # θ: replicated
            pl.BlockSpec((batch_block, d), lambda i: (i, 0)),  # X: batch tile
            pl.BlockSpec((batch_block,), lambda i: (i,)),      # y: batch tile
        ],
        out_specs=[
            pl.BlockSpec((), lambda i: ()),                   # loss: scalar acc
            pl.BlockSpec((d + 1,), lambda i: (0,)),           # grad: accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((), x.dtype),
            jax.ShapeDtypeStruct((d + 1,), x.dtype),
        ],
        interpret=True,
    )(theta, x, y)
    return loss, grad
