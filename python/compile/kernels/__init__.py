"""L1 Pallas kernels + pure-jnp references.

Modules:
  logreg       — fused logistic-regression loss+grad kernel
  softmax_xent — fused softmax-cross-entropy fwd/bwd (custom_vjp)
  ref          — pure-jnp oracles for both
"""

from . import logreg, ref, softmax_xent  # noqa: F401
