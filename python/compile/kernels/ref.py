"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package has an exact functional twin here; pytest
asserts allclose between the two across a hypothesis-driven sweep of
shapes/dtypes. These references are also reused by `model.py` when
``use_kernel=False`` so the whole L2 stack can be cross-checked against a
kernel-free lowering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "logreg_loss_grad_ref",
    "logreg_eval_ref",
    "softmax_xent_ref",
    "softmax_xent_grad_ref",
]


def logreg_loss_grad_ref(theta: jax.Array, x: jax.Array, y: jax.Array,
                         l2: float) -> tuple[jax.Array, jax.Array]:
    """Fused ℓ2-regularized logistic-regression loss + gradient.

    theta: [d+1] flat parameters, ``theta[:-1]`` weights, ``theta[-1]`` bias.
    x: [B, d] features; y: [B] targets in {0, 1} (float).
    Returns (scalar mean loss, [d+1] gradient). The regularizer is
    ``l2/2 * ||theta||^2`` (bias included), matching the paper's
    "regularized logistic regression" objective in §VI-A.
    """
    w, b = theta[:-1], theta[-1]
    z = x @ w + b
    # Numerically-stable BCE-with-logits: max(z,0) - z*y + log1p(exp(-|z|)).
    bce = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    loss = jnp.mean(bce) + 0.5 * l2 * jnp.sum(theta * theta)
    s = jax.nn.sigmoid(z)
    r = (s - y) / x.shape[0]
    gw = x.T @ r + l2 * w
    gb = jnp.sum(r) + l2 * b
    return loss, jnp.concatenate([gw, gb[None]])


def logreg_eval_ref(theta: jax.Array, x: jax.Array, y: jax.Array,
                    l2: float) -> tuple[jax.Array, jax.Array]:
    """Evaluation twin: (mean loss, #correct as int32)."""
    loss, _ = logreg_loss_grad_ref(theta, x, y, l2)
    z = x @ theta[:-1] + theta[-1]
    pred = (z > 0.0).astype(y.dtype)
    correct = jnp.sum((pred == y).astype(jnp.int32))
    return loss, correct


def softmax_xent_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy. logits: [B, V] float; labels: [B] int32."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def softmax_xent_grad_ref(logits: jax.Array, labels: jax.Array,
                          g: jax.Array) -> jax.Array:
    """d(mean xent)/d(logits) scaled by upstream cotangent g (scalar)."""
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return (p - onehot) * (g / logits.shape[0])
