"""Build-time python stack (L2 model + L1 Pallas kernels + AOT lowering).

Never imported at runtime: `make artifacts` runs `compile.aot` once and the
rust coordinator consumes only `artifacts/*.hlo.txt` + `manifest.json`.
"""
