//! Quickstart: train R-FAST over a binary tree through the one
//! `Experiment` builder — on both a closed-form quadratic (exact
//! optimality gap) and the paper's logistic-regression workload. The
//! same chain runs on the wall-clock engine by swapping
//! `.engine(Engine::threaded(pace))` in.
//!
//!     cargo run --release --example quickstart

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;

fn main() {
    // --- 1. Exact convergence on heterogeneous quadratics ---------------
    let topo = Topology::binary_tree(7);
    println!("topology: binary tree, 7 nodes, common roots = {:?}",
             topo.weights.common_roots());

    let cfg = SimConfig {
        seed: 42,
        gamma: 0.02,
        compute_mean: 0.01,
        compute_jitter: 0.3, // heterogeneous paces: full asynchrony
        link_latency: 0.002,
        eval_every: 2.0,
        ..SimConfig::default()
    };
    let run = Experiment::new(
            Workload::Quadratic(QuadSpec { dim: 32, h_min: 0.5, h_max: 2.0,
                                           spread: 1.0, noise: 0.0 }),
            AlgoKind::RFast)
        .topology(&topo)
        .config(cfg)
        .stop(Stop::Iterations(30_000))
        .run()
        .expect("quadratic run");
    println!(
        "quadratic: optimality gap {:.3e} after {} asynchronous wakes \
         ({} messages)",
        run.report.final_gap.unwrap(),
        run.stats.total_steps(),
        run.stats.msgs_delivered.unwrap(),
    );

    // --- 2. The paper's §VI-A logreg workload ----------------------------
    let run = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .topology(&topo)
        .seed(7)
        .stop(Stop::Time(60.0))
        .run()
        .expect("logreg run");
    let loss = &run.report.series["loss_vs_time"];
    let acc = &run.report.series["acc_vs_time"];
    println!(
        "logreg: eval loss {:.4} → {:.4}, accuracy {:.1}%, \
         time-to-loss-0.1 = {:.1}s (virtual)",
        loss.points[0].1,
        loss.last_y().unwrap(),
        100.0 * acc.last_y().unwrap(),
        loss.time_to_reach(0.1).unwrap_or(f64::NAN),
    );
    run.report.save(std::path::Path::new("runs"), "quickstart").unwrap();
    println!("full report: runs/quickstart.json");
}
