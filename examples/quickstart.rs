//! Quickstart: train R-FAST over a binary tree in the virtual-time
//! simulator, on both a closed-form quadratic (exact optimality gap) and
//! the paper's logistic-regression workload.
//!
//!     cargo run --release --example quickstart

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::exp::{run_sim, Workload};
use rfast::graph::Topology;
use rfast::oracle::{GradOracle, QuadraticOracle};
use rfast::sim::{Simulator, StopRule};

fn main() {
    // --- 1. Exact convergence on heterogeneous quadratics ---------------
    let topo = Topology::binary_tree(7);
    println!("topology: binary tree, 7 nodes, common roots = {:?}",
             topo.weights.common_roots());

    let quad = QuadraticOracle::heterogeneous(32, 7, 0.5, 2.0, 42);
    let cfg = SimConfig {
        seed: 42,
        gamma: 0.02,
        compute_mean: 0.01,
        compute_jitter: 0.3, // heterogeneous paces: full asynchrony
        link_latency: 0.002,
        eval_every: 2.0,
        ..SimConfig::default()
    };
    let mut sim = Simulator::new(cfg.clone(), &topo, AlgoKind::RFast,
                                 quad.into_set());
    let report = sim.run(StopRule::Iterations(30_000));
    println!(
        "quadratic: optimality gap {:.3e} after {} asynchronous wakes \
         ({} messages)",
        report.final_gap.unwrap(),
        report.scalars["grad_wakes"],
        report.scalars["msgs_delivered"],
    );

    // --- 2. The paper's §VI-A logreg workload ----------------------------
    let mut cfg = Workload::LogReg.paper_config();
    cfg.seed = 7;
    let report = run_sim(Workload::LogReg, AlgoKind::RFast, &topo, &cfg,
                         StopRule::VirtualTime(60.0));
    let loss = &report.series["loss_vs_time"];
    let acc = &report.series["acc_vs_time"];
    println!(
        "logreg: eval loss {:.4} → {:.4}, accuracy {:.1}%, \
         time-to-loss-0.1 = {:.1}s (virtual)",
        loss.points[0].1,
        loss.last_y().unwrap(),
        100.0 * acc.last_y().unwrap(),
        loss.time_to_reach(0.1).unwrap_or(f64::NAN),
    );
    report.save(std::path::Path::new("runs"), "quickstart").unwrap();
    println!("full report: runs/quickstart.json");
}
