//! Paper §VI-B (Fig 6, Table II straggler columns) as a runnable example:
//! slow one node down 5× and watch the synchronous algorithms stall at the
//! barrier while R-FAST barely notices.
//!
//!     cargo run --release --example straggler_resilience [--nodes N]
//!                                                        [--factor F]

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::exp::{run_sim, Workload};
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::sim::StopRule;

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_default();
    let n: usize = args.parse_num("nodes", 8usize).unwrap();
    let factor: f64 = args.parse_num("factor", 5.0f64).unwrap();
    let topo = Topology::ring(n);

    let algos = [AlgoKind::RFast, AlgoKind::RingAllReduce, AlgoKind::DPsgd,
                 AlgoKind::AdPsgd];
    let target = 0.15; // eval-loss target for "time-to-target"

    let mut table = Table::new(
        &format!("straggler resilience ({n} nodes, one node {factor}× slower)"),
        &["algorithm", "t→target clean (s)", "t→target straggler (s)",
          "slowdown", "steps by straggler / median"],
    );

    for algo in algos {
        let mut time_to = [f64::NAN; 2];
        let mut straggler_ratio = String::new();
        for (k, straggler) in [None, Some((1usize, factor))].iter().enumerate() {
            let mut cfg = Workload::LogReg.paper_config();
            cfg.seed = 3;
            cfg.straggler = *straggler;
            let report = run_sim(Workload::LogReg, algo, &topo, &cfg,
                                 StopRule::TargetLoss {
                                     loss: target,
                                     max_time: 600.0,
                                 });
            time_to[k] = report.series["loss_vs_time"]
                .time_to_reach(target)
                .unwrap_or(f64::INFINITY);
            if straggler.is_some() {
                straggler_ratio = format!(
                    "{:.0} grad wakes total",
                    report.scalars["grad_wakes"]
                );
            }
        }
        table.row(vec![
            algo.name().to_string(),
            format!("{:.1}", time_to[0]),
            format!("{:.1}", time_to[1]),
            format!("{:.2}×", time_to[1] / time_to[0]),
            straggler_ratio,
        ]);
    }
    table.print();
    println!("\nExpected shape (paper Fig 6 / Table II): synchronous \
              algorithms slow down toward {factor}× (barrier waits); \
              asynchronous R-FAST / AD-PSGD stay within ~1.1-1.4× (the \
              residual comes from the slow node's shard being sampled \
              less often, not from waiting).");
}
