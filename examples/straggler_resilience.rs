//! Paper §VI-B (Fig 6, Table II straggler columns) as a runnable example:
//! slow one node down 5× and watch the synchronous algorithms stall at the
//! barrier while R-FAST barely notices. The straggler is injected through
//! the declarative `scenario` layer, so any preset or scenario JSON works:
//!
//!     cargo run --release --example straggler_resilience [--nodes N]
//!                                     [--factor F] [--scenario NAME|FILE]
//!                                     [--engine sim|threaded]
//!
//! e.g. `--scenario late_straggler` (onset at t=60) or `--scenario churn`
//! (pause/resume windows). Without `--scenario`, a permanent single
//! straggler of `--factor` on node 1 is built, matching the paper.
//! `--engine threaded` runs the same comparison on the wall-clock
//! thread-per-node runner (real threads sleeping the straggler factor) —
//! through the SAME `Experiment` chain: only the `.engine(..)` call and
//! the stop deadline differ.

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::exp::{Engine, Experiment, Stop, Workload};
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::scenario::Scenario;

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let n: usize = args.parse_num("nodes", 8usize).unwrap();
    let factor: f64 = args.parse_num("factor", 5.0f64).unwrap();
    let topo = Topology::ring(n);

    let scenario = match args.get("scenario") {
        Some(spec) => Scenario::resolve(spec).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        }),
        None => Scenario::single_straggler(1, factor),
    };

    let target = 0.15; // eval-loss target for "time-to-target"
    let mut cfg = Workload::LogReg.paper_config();
    cfg.seed = 3;
    // engine + stop are the ONLY things that differ between the two
    // clocks; everything else is one shared builder chain
    let (engine, stop) = match args.get_or("engine", "sim").as_str() {
        "sim" => (Engine::Sim,
                  Stop::TargetLoss { loss: target, max_time: 600.0 }),
        "threaded" => {
            // wall clock: pace each local iteration at compute_mean so
            // the cadence matches the simulator's calibration
            cfg.eval_every = 0.25;
            (Engine::threaded(Some(cfg.compute_mean)),
             Stop::TargetLoss { loss: target, max_time: 60.0 })
        }
        other => {
            eprintln!("error: unknown --engine {other:?} (sim|threaded)");
            std::process::exit(2);
        }
    };
    let algos = [AlgoKind::RFast, AlgoKind::RingAllReduce, AlgoKind::DPsgd,
                 AlgoKind::AdPsgd];

    let mut table = Table::new(
        &format!("straggler resilience ({n} nodes, engine: {}, \
                  scenario: {})",
                 engine.name(), scenario.name),
        &["algorithm", "t→target clean (s)", "t→target faulty (s)",
          "slowdown", "grad wakes (faulty)"],
    );

    for algo in algos {
        let mut time_to = [f64::NAN; 2];
        let mut wakes = String::new();
        for (k, sc) in [None, Some(&scenario)].into_iter().enumerate() {
            let run = Experiment::new(Workload::LogReg, algo)
                .topology(&topo)
                .config(cfg.clone())
                .maybe_scenario(sc)
                .engine(engine)
                .stop(stop)
                .run()
                .expect("straggler run");
            let series = run.loss_series().expect("loss series");
            time_to[k] = series.time_to_reach(target).unwrap_or(f64::INFINITY);
            if sc.is_some() {
                wakes = format!("{}", run.stats.total_steps());
            }
        }
        table.row(vec![
            algo.name().to_string(),
            format!("{:.1}", time_to[0]),
            format!("{:.1}", time_to[1]),
            format!("{:.2}×", time_to[1] / time_to[0]),
            wakes,
        ]);
    }
    table.print();
    println!("\nExpected shape (paper Fig 6 / Table II): synchronous \
              algorithms slow down toward the straggler factor (barrier \
              waits); asynchronous R-FAST / AD-PSGD stay within ~1.1-1.4× \
              (the residual comes from the slow node's shard being sampled \
              less often, not from waiting). Scenario presets: \
              `repro scenarios` lists them.");
}
