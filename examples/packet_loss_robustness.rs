//! The robustness claim of §IV (iii): the ρ/ρ̃ running-sum scheme makes
//! gradient tracking immune to packet loss. This example sweeps the loss
//! probability and compares robust R-FAST against the naive-GT ablation
//! (one-shot increments) and OSGP (push-sum, mass-lossy) on heterogeneous
//! quadratics where the exact optimality gap is measurable. Loss is
//! injected through the declarative `scenario` layer; a final row runs a
//! full named preset (default `lossy_30pct`, override with `--scenario`).
//! `--engine threaded` reruns the sweep on the wall-clock thread-per-node
//! runner (gap measured as ‖x̄ − x*‖ of the last evaluated mean).
//!
//!     cargo run --release --example packet_loss_robustness
//!                                     [--scenario NAME|FILE.json]
//!                                     [--engine sim|threaded]

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::cli::Args;
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::oracle::{GradOracle, QuadraticOracle};
use rfast::runner::{RunUntil, ThreadedRunner};
use rfast::scenario::Scenario;
use rfast::sim::{Simulator, StopRule};
use rfast::testutil::{tracking_quad_eval, QuadFactory};

fn cfg_for(seed: u64, scenario: &Scenario) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_cap: 0.05,
        scenario: if scenario.is_empty() { None } else { Some(scenario.clone()) },
        eval_every: 5.0,
        ..SimConfig::default()
    }
}

fn gap(algo: AlgoKind, scenario: &Scenario, seed: u64) -> f64 {
    let topo = Topology::ring(6);
    let quad = QuadraticOracle::new(16, 6, 0.5, 3.0, 1.5, 0.0, seed);
    let cfg = cfg_for(seed, scenario);
    let mut sim = Simulator::new(cfg, &topo, algo, quad.into_set());
    let report = sim.run(StopRule::Iterations(60_000));
    report.final_gap.unwrap()
}

/// Same comparison on the wall-clock runner: distance of the last
/// evaluated mean model to the closed-form optimum.
fn gap_threaded(algo: AlgoKind, scenario: &Scenario, seed: u64) -> f64 {
    let topo = Topology::ring(6);
    let quad = QuadraticOracle::new(16, 6, 0.5, 3.0, 1.5, 0.0, seed);
    let xs = quad.optimum();
    let mut cfg = cfg_for(seed, scenario);
    cfg.eval_every = 0.05;
    let runner = ThreadedRunner::new(cfg, &topo, algo, vec![0.0; 16])
        .with_pace(1e-4);
    let (mut eval, last_mean) = tracking_quad_eval(quad.clone());
    runner.run(&QuadFactory(quad), &mut eval, RunUntil::TotalSteps(15_000));
    rfast::linalg::dist(&last_mean.lock().unwrap(), &xs)
}

fn mean_gap(engine: &str, algo: AlgoKind, scenario: &Scenario) -> f64 {
    if engine == "threaded" {
        // one seed: wall-clock runs are slower and not bitwise-repeatable
        gap_threaded(algo, scenario, 10)
    } else {
        (0..3).map(|s| gap(algo, scenario, 10 + s)).sum::<f64>() / 3.0
    }
}

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let engine = args.get_or("engine", "sim");
    if engine != "sim" && engine != "threaded" {
        eprintln!("error: unknown --engine {engine:?} (sim|threaded)");
        std::process::exit(2);
    }
    let mut table = Table::new(
        &format!("optimality gap vs packet-loss probability (6-node ring, \
                  quadratics, engine: {engine})"),
        &["scenario", "R-FAST (robust ρ)", "naive GT", "OSGP"],
    );
    for loss_prob in [0.0, 0.1, 0.2, 0.3, 0.4] {
        let sc = if loss_prob > 0.0 {
            Scenario::constant_loss(loss_prob)
        } else {
            Scenario::default() // clean baseline
        };
        table.row(vec![
            format!("{:.0}% loss", loss_prob * 100.0),
            format!("{:.3e}", mean_gap(&engine, AlgoKind::RFast, &sc)),
            format!("{:.3e}", mean_gap(&engine, AlgoKind::RFastNaive, &sc)),
            format!("{:.3e}", mean_gap(&engine, AlgoKind::Osgp, &sc)),
        ]);
    }
    // one full named preset on top of the sweep (ramps/churn welcome)
    let preset = args.get("scenario").unwrap_or("lossy_30pct");
    let sc = Scenario::resolve(preset).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    table.row(vec![
        format!("preset: {}", sc.name),
        format!("{:.3e}", mean_gap(&engine, AlgoKind::RFast, &sc)),
        format!("{:.3e}", mean_gap(&engine, AlgoKind::RFastNaive, &sc)),
        format!("{:.3e}", mean_gap(&engine, AlgoKind::Osgp, &sc)),
    ]);
    table.print();
    println!("\nExpected shape: R-FAST's gap is loss-invariant (running sums \
              subsume dropped packets); naive GT and OSGP degrade because \
              dropped increments / push-sum mass are gone forever.");
}
