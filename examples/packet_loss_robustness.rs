//! The robustness claim of §IV (iii): the ρ/ρ̃ running-sum scheme makes
//! gradient tracking immune to packet loss. This example sweeps the loss
//! probability and compares robust R-FAST against the naive-GT ablation
//! (one-shot increments) and OSGP (push-sum, mass-lossy) on heterogeneous
//! quadratics where the exact optimality gap is measurable. Loss is
//! injected through the declarative `scenario` layer; a final row runs a
//! full named preset (default `lossy_30pct`, override with `--scenario`).
//! `--engine threaded` reruns the sweep on the wall-clock thread-per-node
//! runner through the SAME `Experiment` chain — both engines report the
//! gap as `final_gap` (the threaded engine measures it on the last
//! evaluated mean).
//!
//!     cargo run --release --example packet_loss_robustness
//!                                     [--scenario NAME|FILE.json]
//!                                     [--engine sim|threaded]

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::config::SimConfig;
use rfast::exp::{Engine, Experiment, QuadSpec, Stop, Workload};
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::scenario::Scenario;

const SPEC: QuadSpec =
    QuadSpec { dim: 16, h_min: 0.5, h_max: 3.0, spread: 1.5, noise: 0.0 };

fn cfg_for(seed: u64, threaded: bool) -> SimConfig {
    SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_cap: 0.05,
        eval_every: if threaded { 0.05 } else { 5.0 },
        ..SimConfig::default()
    }
}

/// One gap measurement — the engine picks the clock, the chain is shared.
fn gap(engine: Engine, algo: AlgoKind, scenario: &Scenario, seed: u64) -> f64 {
    let threaded = matches!(engine, Engine::Threaded { .. });
    let stop = if threaded {
        Stop::Iterations(15_000)
    } else {
        Stop::Iterations(60_000)
    };
    let run = Experiment::new(Workload::Quadratic(SPEC), algo)
        .topology(&Topology::ring(6))
        .config(cfg_for(seed, threaded))
        .maybe_scenario((!scenario.is_empty()).then_some(scenario))
        .engine(engine)
        .stop(stop)
        .run()
        .expect("gap run");
    run.report.final_gap.unwrap()
}

fn mean_gap(engine: Engine, algo: AlgoKind, scenario: &Scenario) -> f64 {
    if matches!(engine, Engine::Threaded { .. }) {
        // one seed: wall-clock runs are slower and not bitwise-repeatable
        gap(engine, algo, scenario, 10)
    } else {
        (0..3).map(|s| gap(engine, algo, scenario, 10 + s)).sum::<f64>() / 3.0
    }
}

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let engine = match args.get_or("engine", "sim").as_str() {
        "sim" => Engine::Sim,
        "threaded" => Engine::threaded(Some(1e-4)),
        other => {
            eprintln!("error: unknown --engine {other:?} (sim|threaded)");
            std::process::exit(2);
        }
    };
    let mut table = Table::new(
        &format!("optimality gap vs packet-loss probability (6-node ring, \
                  quadratics, engine: {})", engine.name()),
        &["scenario", "R-FAST (robust ρ)", "naive GT", "OSGP"],
    );
    for loss_prob in [0.0, 0.1, 0.2, 0.3, 0.4] {
        let sc = if loss_prob > 0.0 {
            Scenario::constant_loss(loss_prob)
        } else {
            Scenario::default() // clean baseline
        };
        table.row(vec![
            format!("{:.0}% loss", loss_prob * 100.0),
            format!("{:.3e}", mean_gap(engine, AlgoKind::RFast, &sc)),
            format!("{:.3e}", mean_gap(engine, AlgoKind::RFastNaive, &sc)),
            format!("{:.3e}", mean_gap(engine, AlgoKind::Osgp, &sc)),
        ]);
    }
    // one full named preset on top of the sweep (ramps/churn welcome)
    let preset = args.get("scenario").unwrap_or("lossy_30pct");
    let sc = Scenario::resolve(preset).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    table.row(vec![
        format!("preset: {}", sc.name),
        format!("{:.3e}", mean_gap(engine, AlgoKind::RFast, &sc)),
        format!("{:.3e}", mean_gap(engine, AlgoKind::RFastNaive, &sc)),
        format!("{:.3e}", mean_gap(engine, AlgoKind::Osgp, &sc)),
    ]);
    table.print();
    println!("\nExpected shape: R-FAST's gap is loss-invariant (running sums \
              subsume dropped packets); naive GT and OSGP degrade because \
              dropped increments / push-sum mass are gone forever.");
}
