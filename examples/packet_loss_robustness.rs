//! The robustness claim of §IV (iii): the ρ/ρ̃ running-sum scheme makes
//! gradient tracking immune to packet loss. This example sweeps the loss
//! probability and compares robust R-FAST against the naive-GT ablation
//! (one-shot increments) and OSGP (push-sum, mass-lossy) on heterogeneous
//! quadratics where the exact optimality gap is measurable.
//!
//!     cargo run --release --example packet_loss_robustness

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::oracle::{GradOracle, QuadraticOracle};
use rfast::sim::{Simulator, StopRule};

fn gap(algo: AlgoKind, loss_prob: f64, seed: u64) -> f64 {
    let topo = Topology::ring(6);
    let quad = QuadraticOracle::new(16, 6, 0.5, 3.0, 1.5, 0.0, seed);
    let cfg = SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_cap: 0.05,
        loss_prob,
        eval_every: 5.0,
        ..SimConfig::default()
    };
    let mut sim = Simulator::new(cfg, &topo, algo, quad.into_set());
    let report = sim.run(StopRule::Iterations(60_000));
    report.final_gap.unwrap()
}

fn main() {
    let mut table = Table::new(
        "optimality gap vs packet-loss probability (6-node ring, quadratics)",
        &["loss prob", "R-FAST (robust ρ)", "naive GT", "OSGP"],
    );
    for loss_prob in [0.0, 0.1, 0.2, 0.3, 0.4] {
        let robust: f64 =
            (0..3).map(|s| gap(AlgoKind::RFast, loss_prob, 10 + s)).sum::<f64>() / 3.0;
        let naive: f64 =
            (0..3).map(|s| gap(AlgoKind::RFastNaive, loss_prob, 10 + s)).sum::<f64>() / 3.0;
        let osgp: f64 =
            (0..3).map(|s| gap(AlgoKind::Osgp, loss_prob, 10 + s)).sum::<f64>() / 3.0;
        table.row(vec![
            format!("{:.0}%", loss_prob * 100.0),
            format!("{robust:.3e}"),
            format!("{naive:.3e}"),
            format!("{osgp:.3e}"),
        ]);
    }
    table.print();
    println!("\nExpected shape: R-FAST's gap is loss-invariant (running sums \
              subsume dropped packets); naive GT and OSGP degrade because \
              dropped increments / push-sum mass are gone forever.");
}
