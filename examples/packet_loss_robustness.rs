//! The robustness claim of §IV (iii): the ρ/ρ̃ running-sum scheme makes
//! gradient tracking immune to packet loss. This example sweeps the loss
//! probability and compares robust R-FAST against the naive-GT ablation
//! (one-shot increments) and OSGP (push-sum, mass-lossy) on heterogeneous
//! quadratics where the exact optimality gap is measurable. Loss is
//! injected through the declarative `scenario` layer; a final row runs a
//! full named preset (default `lossy_30pct`, override with `--scenario`).
//!
//!     cargo run --release --example packet_loss_robustness
//!                                     [--scenario NAME|FILE.json]

use rfast::algo::AlgoKind;
use rfast::config::SimConfig;
use rfast::cli::Args;
use rfast::graph::Topology;
use rfast::metrics::Table;
use rfast::oracle::{GradOracle, QuadraticOracle};
use rfast::scenario::Scenario;
use rfast::sim::{Simulator, StopRule};

fn gap(algo: AlgoKind, scenario: &Scenario, seed: u64) -> f64 {
    let topo = Topology::ring(6);
    let quad = QuadraticOracle::new(16, 6, 0.5, 3.0, 1.5, 0.0, seed);
    let cfg = SimConfig {
        seed,
        gamma: 0.03,
        compute_mean: 0.01,
        compute_jitter: 0.3,
        link_latency: 0.002,
        latency_cap: 0.05,
        scenario: if scenario.is_empty() { None } else { Some(scenario.clone()) },
        eval_every: 5.0,
        ..SimConfig::default()
    };
    let mut sim = Simulator::new(cfg, &topo, algo, quad.into_set());
    let report = sim.run(StopRule::Iterations(60_000));
    report.final_gap.unwrap()
}

fn mean_gap(algo: AlgoKind, scenario: &Scenario) -> f64 {
    (0..3).map(|s| gap(algo, scenario, 10 + s)).sum::<f64>() / 3.0
}

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let mut table = Table::new(
        "optimality gap vs packet-loss probability (6-node ring, quadratics)",
        &["scenario", "R-FAST (robust ρ)", "naive GT", "OSGP"],
    );
    for loss_prob in [0.0, 0.1, 0.2, 0.3, 0.4] {
        let sc = if loss_prob > 0.0 {
            Scenario::constant_loss(loss_prob)
        } else {
            Scenario::default() // clean baseline
        };
        table.row(vec![
            format!("{:.0}% loss", loss_prob * 100.0),
            format!("{:.3e}", mean_gap(AlgoKind::RFast, &sc)),
            format!("{:.3e}", mean_gap(AlgoKind::RFastNaive, &sc)),
            format!("{:.3e}", mean_gap(AlgoKind::Osgp, &sc)),
        ]);
    }
    // one full named preset on top of the sweep (ramps/churn welcome)
    let preset = args.get("scenario").unwrap_or("lossy_30pct");
    let sc = Scenario::resolve(preset).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    table.row(vec![
        format!("preset: {}", sc.name),
        format!("{:.3e}", mean_gap(AlgoKind::RFast, &sc)),
        format!("{:.3e}", mean_gap(AlgoKind::RFastNaive, &sc)),
        format!("{:.3e}", mean_gap(AlgoKind::Osgp, &sc)),
    ]);
    table.print();
    println!("\nExpected shape: R-FAST's gap is loss-invariant (running sums \
              subsume dropped packets); naive GT and OSGP degrade because \
              dropped increments / push-sum mass are gone forever.");
}
