//! Paper §VI-A (Fig 4a) as a runnable example: R-FAST trains the same
//! logistic-regression problem over five different topologies — including
//! the NON-strongly-connected binary tree and line graphs that only
//! Assumption 2 permits.
//!
//!     cargo run --release --example topologies_logreg [--nodes N]

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::exp::{run_sim, save_comparison_csvs, Workload};
use rfast::graph::TopologyKind;
use rfast::metrics::Table;
use rfast::sim::StopRule;
use std::path::Path;

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_default();
    let n: usize = args.parse_num("nodes", 7usize).unwrap();

    let kinds = [
        TopologyKind::BinaryTree,
        TopologyKind::Line,
        TopologyKind::Ring,
        TopologyKind::Exponential,
        TopologyKind::Mesh,
    ];

    let mut table = Table::new(
        &format!("R-FAST over general topologies ({n} nodes, logreg)"),
        &["topology", "common roots", "final loss", "final acc(%)",
          "epochs", "time→0.1 (s)"],
    );
    let mut reports = Vec::new();
    for kind in kinds {
        let topo = kind.build(n);
        let mut cfg = Workload::LogReg.paper_config();
        cfg.seed = 1;
        let report = run_sim(Workload::LogReg, AlgoKind::RFast, &topo, &cfg,
                             StopRule::VirtualTime(120.0));
        let loss = &report.series["loss_vs_time"];
        let acc = &report.series["acc_vs_time"];
        table.row(vec![
            kind.name().to_string(),
            format!("{:?}", topo.weights.common_roots()),
            format!("{:.4}", loss.last_y().unwrap()),
            format!("{:.1}", 100.0 * acc.last_y().unwrap()),
            format!("{:.0}", report.scalars["epoch"]),
            loss.time_to_reach(0.1)
                .map(|t| format!("{t:.1}"))
                .unwrap_or_else(|| "—".into()),
        ]);
        let mut r = report;
        r.label = kind.name().to_string();
        reports.push(r);
    }
    table.print();
    let refs: Vec<&_> = reports.iter().collect();
    save_comparison_csvs(Path::new("runs"), "topologies", &refs).unwrap();
    println!("\ncurves: runs/topologies_loss_vs_epoch.csv (and friends)");
    println!("Every topology converges — including tree/line, which are NOT \
              strongly connected (Assumption 2 at work).");
}
