//! Paper §VI-A (Fig 4a) as a runnable example: R-FAST trains the same
//! logistic-regression problem over five different topologies — including
//! the NON-strongly-connected binary tree and line graphs that only
//! Assumption 2 permits — then over asymmetric (G_R, G_C) architecture
//! pairs whose pull and push graphs are two DIFFERENT spanning trees
//! (paper Fig. 3; `graph::arch`). One sweep-native builder chain drives
//! each set.
//!
//!     cargo run --release --example topologies_logreg [--nodes N]

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::exp::{Experiment, Stop, Workload};
use rfast::graph::{ArchSpec, TopologyKind};
use rfast::metrics::Table;
use std::path::Path;

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_default();
    let n: usize = args.parse_num("nodes", 7usize).unwrap();

    let kinds = [
        TopologyKind::BinaryTree,
        TopologyKind::Line,
        TopologyKind::Ring,
        TopologyKind::Exponential,
        TopologyKind::Mesh,
    ];

    let cmp = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .seed(1)
        .stop(Stop::Time(120.0))
        .sweep_topologies(&kinds, n)
        .expect("topology sweep");

    let mut table = Table::new(
        &format!("R-FAST over general topologies ({n} nodes, logreg)"),
        &["topology", "common roots", "final loss", "final acc(%)",
          "epochs", "time→0.1 (s)"],
    );
    for (kind, run) in kinds.iter().zip(&cmp.runs) {
        let topo = kind.build(n);
        let loss = &run.report.series["loss_vs_time"];
        let acc = &run.report.series["acc_vs_time"];
        table.row(vec![
            run.report.label.clone(),
            format!("{:?}", topo.weights.common_roots()),
            format!("{:.4}", loss.last_y().unwrap()),
            format!("{:.1}", 100.0 * acc.last_y().unwrap()),
            format!("{:.0}", run.report.scalars["epoch"]),
            loss.time_to_reach(0.1)
                .map(|t| format!("{t:.1}"))
                .unwrap_or_else(|| "—".into()),
        ]);
    }
    table.print();
    cmp.save_csvs(Path::new("runs"), "topologies").unwrap();
    println!("\ncurves: runs/topologies_loss_vs_epoch.csv (and friends)");
    println!("Every topology converges — including tree/line, which are NOT \
              strongly connected (Assumption 2 at work).");

    // part 2: the pull and push graphs need not even be the same tree —
    // any two spanning trees sharing a common root satisfy Assumption 2
    let pairs = ArchSpec::paper_pairs();
    let arch_cmp = Experiment::new(Workload::LogReg, AlgoKind::RFast)
        .seed(1)
        .stop(Stop::Time(120.0))
        .sweep_architectures(&pairs, n)
        .expect("architecture sweep");
    let mut arch_table = Table::new(
        &format!("R-FAST over asymmetric pull+push pairs ({n} nodes)"),
        &["architecture", "final loss", "final acc(%)"],
    );
    for run in &arch_cmp.runs {
        arch_table.row(vec![
            run.report.label.clone(),
            format!("{:.4}",
                    run.report.series["loss_vs_time"].last_y().unwrap()),
            format!("{:.1}",
                    100.0 * run.report.series["acc_vs_time"]
                        .last_y()
                        .unwrap()),
        ]);
    }
    arch_table.print();
    arch_cmp.save_csvs(Path::new("runs"), "architectures").unwrap();
    println!("G_R and G_C as two different spanning trees (Fig. 3): \
              runs/architectures_*.csv");
}
