//! END-TO-END DRIVER (DESIGN.md §3): train a decoder-only transformer LM
//! across asynchronous R-FAST nodes using the **full production stack** —
//!
//!   L1  Pallas softmax-xent kernel (inside the AOT-lowered fwd/bwd)
//!   L2  JAX transformer over flat θ, lowered once to HLO text
//!   RT  rust PJRT runtime: each worker thread compiles + executes the
//!       `transformer_*_grad` artifact (python is NOT running)
//!   L3  R-FAST coordinator on the real thread-per-node runner
//!
//! on a synthetic Markov-chain corpus (achievable xent ≈ log(branching)
//! ≪ log(vocab), so the loss curve shows genuine learning). The loss curve
//! lands in runs/e2e_transformer.csv and is recorded in EXPERIMENTS.md.
//!
//!     make artifacts                       # lower the model (once)
//!     cargo run --release --example e2e_transformer -- \
//!         [--scale tiny|e2e|large] [--nodes 4] [--steps 400] [--gamma 0.3]

use rfast::algo::AlgoKind;
use rfast::cli::Args;
use rfast::config::SimConfig;
use rfast::graph::Topology;
use rfast::metrics::save_series_csv;
use rfast::oracle::Eval;
use rfast::exp::Stop;
use rfast::runner::ThreadedRunner;
use rfast::runtime::{self, Engine, Input, Manifest, PjrtFactory, PjrtTask};
use std::path::Path;

fn main() {
    let args = Args::parse_opts(std::env::args().skip(1)).unwrap_or_default();
    let scale = args.get_or("scale", "e2e");
    let nodes: usize = args.parse_num("nodes", 4usize).unwrap();
    let steps: u64 = args.parse_num("steps", 400u64).unwrap();
    let gamma: f32 = args.parse_num("gamma", 0.3f32).unwrap();

    let dir = runtime::default_artifact_dir()
        .expect("no artifacts/ found — run `make artifacts` first");
    let manifest = Manifest::load(&dir).expect("manifest");
    let model = format!("transformer_{scale}");
    if !manifest.models.contains_key(&model) {
        eprintln!(
            "artifact set has no {model}; re-run `make artifacts \
             TRANSFORMER_SCALE={scale}`"
        );
        std::process::exit(1);
    }
    let info = manifest.model(&model).unwrap();
    println!(
        "e2e: {} ({} params) over {} asynchronous R-FAST nodes, {} steps",
        model, info.p, nodes, steps
    );

    // Workload: shared Markov chain, per-node independent walks.
    let task = PjrtTask::Transformer {
        scale: scale.clone(),
        vocab: manifest
            .artifact(&format!("{model}_grad"))
            .unwrap()
            .meta
            .at(&["config", "vocab"])
            .and_then(|v| v.as_usize())
            .unwrap_or(512),
        branching: 4,
    };
    let factory = PjrtFactory::new(manifest.clone(), task.clone(), 11)
        .expect("factory");
    let x0 = manifest.load_init(&model).expect("init θ");

    // Evaluation engine on the coordinator thread (own PJRT client).
    let eval_name = task.eval_artifact();
    let eval_engine = Engine::load(&manifest, &[&eval_name]).expect("eval engine");
    let espec = eval_engine.artifact_info(&eval_name).unwrap().clone();
    let mut eval_stream = rfast::data::TokenStream::new(
        match &task {
            PjrtTask::Transformer { vocab, .. } => *vocab,
            _ => unreachable!(),
        },
        4,
        11,
    )
    .for_node(999, 11 ^ 0xe7a1);
    let eval_blocks: Vec<Vec<i32>> = (0..4)
        .map(|_| eval_stream.next_block(espec.inputs[1].shape[0],
                                        espec.inputs[1].shape[1]))
        .collect();
    let mut eval_fn = move |x: &[f32]| {
        let mut total = 0.0;
        for b in &eval_blocks {
            let out = eval_engine
                .run(&eval_name, &[Input::F32(x), Input::I32(b)])
                .expect("eval exec");
            total += out[0].scalar_f32().unwrap() as f64;
        }
        Eval { loss: total / eval_blocks.len() as f64, accuracy: None }
    };

    let cfg = SimConfig {
        seed: 11,
        gamma,
        compute_mean: 0.001, // real pace = actual XLA execution time
        eval_every: 2.0,
        ..SimConfig::default()
    };
    let topo = Topology::ring(nodes);
    let runner = ThreadedRunner::new(cfg, &topo, AlgoKind::RFast, x0);

    let t0 = std::time::Instant::now();
    let (report, stats) =
        runner.run(&factory, &mut eval_fn, Stop::Iterations(steps));
    let wall = t0.elapsed().as_secs_f64();

    let s = &report.series["loss_vs_wall"];
    println!("\nloss curve (eval xent on held-out blocks):");
    for &(t, y) in &s.points {
        println!("  t={t:7.1}s  loss={y:.4}");
    }
    let vocab_ln = match &task {
        PjrtTask::Transformer { vocab, .. } => (*vocab as f64).ln(),
        _ => unreachable!(),
    };
    println!(
        "\nsteps/node: {:?}  ({:.1} steps/s aggregate, wall {wall:.0}s)",
        stats.steps_per_node,
        stats.steps_per_node.iter().sum::<u64>() as f64 / wall
    );
    println!(
        "uniform-baseline xent = ln(vocab) = {:.3}; final = {:.3} \
         (structure learned: {})",
        vocab_ln,
        s.last_y().unwrap(),
        if s.last_y().unwrap() < vocab_ln - 0.5 { "YES" } else { "not yet" }
    );
    save_series_csv(Path::new("runs/e2e_transformer.csv"), &[s]).unwrap();
    report.save(Path::new("runs"), "e2e_transformer").unwrap();
    println!("curve: runs/e2e_transformer.csv");
}
